"""Trace export smoke: one tiny traced offload, then validate the JSON.

``make trace-smoke`` runs this. It exercises the full tracing path — device
virtual-time events, dispatcher/worker host spans, Chrome export — on a
deliberately tiny array offload, then checks the exported file is valid
Chrome ``trace_event`` JSON (the schema Perfetto / chrome://tracing load):
a ``traceEvents`` list whose entries carry name/ph/pid/tid/ts, complete
events carry dur, and both the host (pid 1) and device virtual-time (pid 2)
processes are present with metadata rows.
"""
from __future__ import annotations

import json
import sys

import numpy as np

from repro.array import OffloadScheduler, StripedZoneArray
from repro.core import filter_count
from repro.telemetry import trace as _trace
from repro.zns import ZonedDevice

OUT_PATH = "TRACE_smoke.json"
DATA_BYTES = 1 * 1024 * 1024
VALID_PHASES = {"X", "M", "i"}


def run_traced_offload() -> int:
    rng = np.random.default_rng(0)
    data = rng.integers(0, 2**31 - 1, DATA_BYTES // 4, dtype=np.int32)
    expected = int((data > 2**30).sum())
    devices = [ZonedDevice(num_zones=1, zone_bytes=DATA_BYTES,
                           block_bytes=4096, read_us_per_block=1.0)
               for _ in range(2)]
    with StripedZoneArray(devices, stripe_blocks=16) as array:
        array.zone_append(0, data)
        with OffloadScheduler(array) as sched:
            program = filter_count("int32", "gt", 2**30)
            sched.nvm_cmd_bpf_run(program, 0)      # warm-up outside the trace
            _trace.clear()
            with _trace.tracing(True):
                sched.nvm_cmd_bpf_run(program, 0)
            assert int(sched.nvm_cmd_bpf_result()) == expected
    n = _trace.export_chrome(OUT_PATH)
    _trace.clear()
    return n


def validate(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    assert isinstance(doc, dict), "trace root must be an object"
    evs = doc["traceEvents"]
    assert isinstance(evs, list) and evs, "traceEvents missing or empty"
    pids = set()
    names = set()
    n_complete = 0
    for e in evs:
        assert isinstance(e["name"], str) and e["name"]
        assert e["ph"] in VALID_PHASES, f"unexpected phase {e['ph']!r}"
        assert isinstance(e["pid"], int)
        pids.add(e["pid"])
        if e["ph"] == "M":
            continue
        assert isinstance(e["tid"], int)
        assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
        if e["ph"] == "X":
            assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
            n_complete += 1
            names.add(e["name"])
    assert {1, 2} <= pids, "host (pid 1) and device (pid 2) rows expected"
    # the offload must have produced both host spans and device virtual time
    assert "offload.execute" in names, f"no offload.execute span in {names}"
    assert "dev.read" in names, f"no dev.read virtual event in {names}"
    meta = [e for e in evs if e["ph"] == "M"]
    assert any(m["name"] == "process_name" for m in meta)
    assert doc["otherData"]["dropped_events"] == 0
    return {"events": len(evs), "complete": n_complete,
            "span_names": sorted(names)}


def main() -> int:
    n = run_traced_offload()
    info = validate(OUT_PATH)
    print(f"trace-smoke: wrote {OUT_PATH} ({n} events, "
          f"{info['complete']} complete) — schema OK")
    print(f"trace-smoke: spans: {', '.join(info['span_names'])}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
