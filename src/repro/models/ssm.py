"""Mamba2 (SSD — state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: quadratic attention-like
computation *within* chunks (matrix form, MXU-friendly) plus a linear
recurrence over chunk states (lax.scan). Decode is the O(1) recurrent update
on a persistent ``[B, heads, head_dim, state]`` SSM state plus a depthwise
conv ring state — the bounded-state property that makes ``long_500k``
runnable for this arch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import cdtype
from repro.models.config import ModelConfig
from repro.models.params import ParamSpec
from repro.sharding import shard_act, use_param

__all__ = ["ssm_specs", "apply_ssm", "ssm_decode_step", "ssm_cache_specs"]


def ssm_specs(cfg: ModelConfig) -> dict:
    d, di, ds, nh, kc = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                         cfg.ssm_heads, cfg.ssm_conv)
    return {
        "wz": ParamSpec((d, di), ("embed", "ssm_inner"), init="fan_in"),
        "wx": ParamSpec((d, di), ("embed", "ssm_inner"), init="fan_in"),
        "wB": ParamSpec((d, ds), ("embed", "ssm_state"), init="fan_in"),
        "wC": ParamSpec((d, ds), ("embed", "ssm_state"), init="fan_in"),
        "wdt": ParamSpec((d, nh), ("embed", "ssm_heads"), init="fan_in"),
        "conv_x": ParamSpec((kc, di), ("conv", "ssm_inner"), init="fan_in"),
        "conv_B": ParamSpec((kc, ds), ("conv", "ssm_state"), init="fan_in"),
        "conv_C": ParamSpec((kc, ds), ("conv", "ssm_state"), init="fan_in"),
        "A_log": ParamSpec((nh,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "D": ParamSpec((nh,), ("ssm_heads",), init="ones", dtype=jnp.float32),
        "dt_bias": ParamSpec((nh,), ("ssm_heads",), init="zeros", dtype=jnp.float32),
        "norm": ParamSpec((di,), ("ssm_inner",), init="ones"),
        "wo": ParamSpec((di, d), ("ssm_inner", "embed"), init="fan_in"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: [B, L, D]; w: [K, D]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out)


def _gated_rmsnorm(y: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                   eps: float = 1e-6) -> jnp.ndarray:
    g = (y * jax.nn.silu(z)).astype(jnp.float32)
    g = g * jax.lax.rsqrt((g ** 2).mean(-1, keepdims=True) + eps)
    return (g * scale.astype(jnp.float32)).astype(y.dtype)


def _project(cfg: ModelConfig, p: dict, u: jnp.ndarray):
    dt_ = cdtype(cfg)
    z = u @ use_param(p["wz"], ("embed", "ssm_inner")).astype(dt_)
    x = u @ use_param(p["wx"], ("embed", "ssm_inner")).astype(dt_)
    Bm = u @ use_param(p["wB"], ("embed", "ssm_state")).astype(dt_)
    Cm = u @ use_param(p["wC"], ("embed", "ssm_state")).astype(dt_)
    dt_raw = (u @ use_param(p["wdt"], ("embed", "ssm_heads")).astype(dt_)).astype(jnp.float32)
    return z, x, Bm, Cm, dt_raw


def apply_ssm(cfg: ModelConfig, p: dict, u: jnp.ndarray,
              return_cache: bool = False):
    """u: [B, L, d_model]. Chunked SSD scan (training / prefill).
    With ``return_cache``, also returns the decode cache (conv tail +
    final SSM state) so prefill hands off to the recurrent decode path."""
    B, L, _ = u.shape
    nh, hp, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    cl = min(cfg.ssm_chunk, L)
    assert L % cl == 0, f"seq {L} must be a multiple of ssm_chunk {cl}"
    nc = L // cl

    z, x, Bm, Cm, dt_raw = _project(cfg, p, u)
    pre_conv = jnp.concatenate([x, Bm, Cm], axis=-1) if return_cache else None
    x = _causal_conv(x, p["conv_x"].astype(x.dtype))
    Bm = _causal_conv(Bm, p["conv_B"].astype(Bm.dtype))
    Cm = _causal_conv(Cm, p["conv_C"].astype(Cm.dtype))
    x = shard_act(x, ("act_batch", "act_seq", "act_ssm_inner"))

    dt = jax.nn.softplus(dt_raw + p["dt_bias"])                  # [B, L, nh] f32
    A = -jnp.exp(p["A_log"])                                     # [nh] f32
    dA = dt * A                                                  # [B, L, nh]

    # chunk everything: [B, nc, cl, ...]
    xh = x.reshape(B, nc, cl, nh, hp)
    Bc = Bm.reshape(B, nc, cl, ds)
    Cc = Cm.reshape(B, nc, cl, ds)
    dtc = dt.reshape(B, nc, cl, nh)
    dAc = dA.reshape(B, nc, cl, nh)

    cs = jnp.cumsum(dAc, axis=2)                                 # [B,nc,cl,nh]
    # intra-chunk (quadratic, MXU): M[i,j] = (C_i.B_j) exp(cs_i - cs_j) dt_j, i>=j
    Gm = jnp.einsum("bcis,bcjs->bcij", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))
    decay = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])  # [B,nc,i,j,nh]
    tri = jnp.tril(jnp.ones((cl, cl), bool))
    M = jnp.where(tri[None, None, :, :, None],
                  Gm[..., None] * decay * dtc[:, :, None, :, :], 0.0)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", M.astype(cdtype(cfg)), xh,
                         preferred_element_type=jnp.float32)

    # chunk boundary states: S_c = sum_j exp(cs_last - cs_j) dt_j B_j (x) x_j
    decay_last = jnp.exp(cs[:, :, -1:, :] - cs) * dtc            # [B,nc,cl,nh]
    S = jnp.einsum("bcjh,bcjhp,bcjs->bchps",
                   decay_last.astype(cdtype(cfg)), xh, Bc,
                   preferred_element_type=jnp.float32)           # [B,nc,nh,hp,ds]

    # inter-chunk linear recurrence over chunk states
    Tc = jnp.exp(cs[:, :, -1, :])                                # [B,nc,nh]

    def step(H, inp):
        S_c, T_c = inp
        H_prev = H
        H = H * T_c[:, :, None, None] + S_c
        return H, H_prev

    H0 = jnp.zeros((B, nh, hp, ds), jnp.float32)
    H_last, H_prev = jax.lax.scan(step, H0,
                                  (S.swapaxes(0, 1), Tc.swapaxes(0, 1)))
    H_prev = H_prev.swapaxes(0, 1)                               # [B,nc,nh,hp,ds]

    y_off = jnp.einsum("bcis,bchps->bcihp", Cc.astype(jnp.float32), H_prev)
    y_off = y_off * jnp.exp(cs)[..., None]

    y = (y_intra + y_off).reshape(B, L, nh, hp)
    y = y + (p["D"][None, None, :, None] * x.reshape(B, L, nh, hp).astype(jnp.float32))
    y = y.reshape(B, L, nh * hp).astype(cdtype(cfg))
    y = _gated_rmsnorm(y, z, p["norm"])
    out = y @ use_param(p["wo"], ("ssm_inner", "embed")).astype(y.dtype)
    if return_cache:
        kc = cfg.ssm_conv
        tail = pre_conv[:, L - (kc - 1):, :] if L >= kc - 1 else jnp.pad(
            pre_conv, ((0, 0), (kc - 1 - L, 0), (0, 0)))
        return out, {"conv": tail, "state": H_last}
    return out


# ------------------------------------------------------------------- decode

def ssm_cache_specs(cfg: ModelConfig, batch: int) -> dict:
    """Abstract cache for one SSM layer."""
    di, ds, nh, hp, kc = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                          cfg.ssm_head_dim, cfg.ssm_conv)
    return {
        "conv": jax.ShapeDtypeStruct((batch, kc - 1, di + 2 * ds),
                                     jnp.dtype(cfg.compute_dtype)),
        "state": jax.ShapeDtypeStruct((batch, nh, hp, ds), jnp.float32),
    }


def ssm_decode_step(cfg: ModelConfig, p: dict, u: jnp.ndarray, cache: dict):
    """u: [B, 1, d_model]; O(1) recurrent update."""
    B = u.shape[0]
    nh, hp, ds, di, kc = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                          cfg.d_inner, cfg.ssm_conv)
    z, x, Bm, Cm, dt_raw = _project(cfg, p, u)
    feat = jnp.concatenate([x, Bm, Cm], axis=-1)[:, 0, :]        # [B, di+2ds]
    hist = jnp.concatenate([cache["conv"], feat[:, None, :]], axis=1)  # [B,kc,*]
    w = jnp.concatenate(
        [p["conv_x"], p["conv_B"], p["conv_C"]], axis=1).astype(feat.dtype)
    conv_out = jax.nn.silu(jnp.einsum("bkd,kd->bd", hist, w))
    x1, B1, C1 = jnp.split(conv_out, [di, di + ds], axis=-1)

    dt = jax.nn.softplus(dt_raw[:, 0, :] + p["dt_bias"])         # [B, nh]
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * A)                                         # [B, nh]
    xh = x1.reshape(B, nh, hp).astype(jnp.float32)
    state = cache["state"] * da[:, :, None, None] + jnp.einsum(
        "bh,bhp,bs->bhps", dt, xh, B1.astype(jnp.float32))
    y = jnp.einsum("bs,bhps->bhp", C1.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, 1, di).astype(cdtype(cfg))
    y = _gated_rmsnorm(y, z, p["norm"])
    new_cache = {"conv": hist[:, 1:, :], "state": state}
    return y @ p["wo"].astype(y.dtype), new_cache
