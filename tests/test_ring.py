"""Async completion-ring device model: reactor ordering, per-zone
serialization under concurrency, determinism vs the synchronous path, raw
I/O through the scheduler queues, and async checkpoint save/restore."""
import threading
import time

import numpy as np
import pytest

from repro.array import OffloadScheduler, StripedZoneArray
from repro.core import CsdTier, NvmCsd, RingReader, filter_count, run_oracle
from repro.train.checkpoint import ZonedCheckpointStore
from repro.zns import (
    CompletionRing,
    IoFuture,
    IoReactor,
    ZonedDevice,
    payload_as_uint8,
)

BLOCK = 4096


def make_device(n_blocks=64, num_zones=4, **kw):
    kw.setdefault("reactor", IoReactor("test"))
    return ZonedDevice(num_zones=num_zones, zone_bytes=n_blocks * BLOCK,
                       block_bytes=BLOCK, **kw)


def typed_blocks(n_blocks, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(-1000, 1000, n_blocks * BLOCK // 4, dtype=np.int32)


# ---------------------------------------------------------------- reactor core

def test_reactor_retires_in_deadline_order():
    reactor = IoReactor("order")
    ring = CompletionRing(depth=16)
    now = time.monotonic()
    futs = [IoFuture(op="t", zone_id=i, ring=ring) for i in range(4)]
    for f, delay in zip(futs, (0.04, 0.01, 0.03, 0.02)):
        f._value = f.zone_id
        reactor.schedule(f, now + delay)
    assert all(f.result(timeout=5) is not None or True for f in futs)
    order = [f.zone_id for f in ring.drain()]
    assert order == [1, 3, 2, 0]            # deadline order, not submit order
    reactor.close()


def test_zero_service_completes_inline():
    dev = make_device()
    dev.zone_append(0, typed_blocks(8))
    fut = dev.submit_read(0, 0, 8)
    assert fut.done()                       # no emulation -> retired at submit
    assert fut.service_seconds == 0.0
    assert dev.reactor.in_flight == 0


def test_future_value_raises_before_done_error_surface():
    reactor = IoReactor("err")
    fut = IoFuture(op="t")
    fut.fail(RuntimeError("boom"))
    with pytest.raises(RuntimeError, match="boom"):
        fut.result()
    assert fut.error is not None
    reactor.close()


def test_completion_ring_bounded_with_drop_accounting():
    ring = CompletionRing(depth=2)
    for i in range(5):
        IoFuture(op="t", zone_id=i, ring=ring).complete(i)
    assert len(ring) == 2 and ring.dropped == 3 and ring.retired == 5
    assert [f.zone_id for f in ring.drain()] == [3, 4]


# ------------------------------------------------- submit paths vs sync paths

def test_submit_read_bit_identical_to_sync_read():
    dev = make_device(read_us_per_block=20.0)
    data = typed_blocks(32, seed=1)
    dev.zone_append(0, data)
    sync = dev.read_blocks_view(0, 3, 17)
    fut = dev.submit_read(0, 3, 17)
    assert np.array_equal(np.asarray(fut.result(timeout=5)), np.asarray(sync))
    assert not fut.result().flags.writeable
    typed = dev.submit_read(0, 3, 17, dtype=np.int32).result(timeout=5)
    assert np.array_equal(typed, dev.read_extent(0, 3, 17, np.int32))


def test_submit_append_lands_like_sync_append():
    dev = make_device(append_us_per_block=20.0)
    a, b = typed_blocks(4, seed=2), typed_blocks(4, seed=3)
    f1 = dev.submit_append(0, a)
    f2 = dev.submit_append(0, b)
    assert f1.submitted_block == 0 and f2.submitted_block == 4
    assert f1.result(timeout=5) == 0 and f2.result(timeout=5) == 4
    assert np.array_equal(dev.read_extent(0, 4, 4, np.int32), b)


def test_payload_as_uint8_coercions_agree():
    arr = np.arange(16, dtype=np.int64).reshape(4, 4)[:, :2]  # non-contiguous
    via_bytes = payload_as_uint8(arr.copy().tobytes())
    via_array = payload_as_uint8(arr)
    assert via_array.dtype == np.uint8 and via_array.ndim == 1
    assert np.array_equal(via_bytes, via_array)


# ------------------------------------------------------- concurrency stress

@pytest.mark.slow
def test_per_zone_ordering_and_no_lost_completions_shared_zone():
    """N concurrent submitters over ONE zone: completions retire in virtual-
    deadline order (strictly increasing per zone), and none are lost."""
    dev = make_device(n_blocks=256, read_us_per_block=5.0)
    dev.zone_append(0, typed_blocks(256, seed=4))
    ring = CompletionRing(depth=1024)
    n_threads, per_thread = 8, 16
    barrier = threading.Barrier(n_threads)

    def submitter(t):
        barrier.wait()
        for i in range(per_thread):
            dev.submit_read(0, (t * per_thread + i) % 128, 1, ring=ring)

    threads = [threading.Thread(target=submitter, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    total = n_threads * per_thread
    assert ring.wait_retired(total, timeout=30)
    comps = ring.drain()
    assert len(comps) == total              # no lost completions
    deadlines = [f.deadline for f in comps]
    assert deadlines == sorted(deadlines)   # retire order == deadline order
    assert len(set(deadlines)) == total     # same zone: strictly increasing
    assert all(f.error is None for f in comps)


@pytest.mark.slow
def test_disjoint_zone_submitters_deterministic_vs_sync():
    """Concurrent submitters over DISJOINT zones: every completion carries
    exactly the bytes the synchronous path reads, and per-zone order holds."""
    dev = make_device(n_blocks=64, num_zones=8, read_us_per_block=2.0)
    datas = {z: typed_blocks(64, seed=10 + z) for z in range(8)}
    for z, d in datas.items():
        dev.zone_append(z, d)
    ring = CompletionRing(depth=1024)
    reads_per_zone = 6

    def submitter(z):
        for i in range(reads_per_zone):
            dev.submit_read(z, i * 8, 8, dtype=np.int32, ring=ring)

    threads = [threading.Thread(target=submitter, args=(z,)) for z in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert ring.wait_retired(8 * reads_per_zone, timeout=30)
    comps = ring.drain()
    assert len(comps) == 8 * reads_per_zone
    per_zone_deadlines: dict[int, list] = {}
    per_block = BLOCK // 4
    for f in comps:
        want = datas[f.zone_id][f.block_off * per_block:
                                (f.block_off + f.nblocks) * per_block]
        assert np.array_equal(f.value, want)
        per_zone_deadlines.setdefault(f.zone_id, []).append(f.deadline)
    for z, ds in per_zone_deadlines.items():
        assert ds == sorted(ds), f"zone {z} completions out of order"


@pytest.mark.slow
def test_one_reactor_thread_drives_many_in_flight():
    """The tentpole claim: in-flight depth >> worker threads. 32 reads over
    32 zones from ONE submitter thread overlap on the reactor."""
    reactor = IoReactor("depth")
    dev = ZonedDevice(num_zones=32, zone_bytes=8 * BLOCK, block_bytes=BLOCK,
                      read_us_per_block=2500.0, reactor=reactor)  # 20ms/zone
    for z in range(32):
        dev.zone_append(z, typed_blocks(8, seed=z))
    t0 = time.perf_counter()
    futs = [dev.submit_read(z, 0, 8) for z in range(32)]
    for f in futs:
        f.result(timeout=30)
    wall = time.perf_counter() - t0
    # serialized this is 32 x 20ms = 640ms; in flight it is ~one service time
    assert wall < 0.32, f"32 in-flight reads took {wall:.3f}s (serialized?)"
    assert reactor.max_in_flight >= 16
    reactor.close()


# ------------------------------------------------------------- striped array

def test_array_submit_read_matches_sync_striped_read():
    devs = [make_device(n_blocks=32, read_us_per_block=3.0) for _ in range(3)]
    arr = StripedZoneArray(devs, stripe_blocks=4)
    data = typed_blocks(48, seed=20)
    arr.zone_append(0, data)
    sync = arr.read_blocks(0, 5, 31)
    fut = arr.submit_read(0, 5, 31)
    got = fut.result(timeout=10)
    assert np.array_equal(np.asarray(got), sync)
    assert not got.flags.writeable
    typed = arr.submit_read(0, 0, 48, dtype=np.int32).result(timeout=10)
    assert np.array_equal(typed, data)


def test_array_submit_append_equivalent_to_sync():
    data = typed_blocks(24, seed=21)
    sync_devs = [make_device(n_blocks=16) for _ in range(2)]
    async_devs = [make_device(n_blocks=16, append_us_per_block=10.0)
                  for _ in range(2)]
    sync_arr = StripedZoneArray(sync_devs, stripe_blocks=4)
    async_arr = StripedZoneArray(async_devs, stripe_blocks=4)
    assert sync_arr.zone_append(0, data) == 0
    fut = async_arr.submit_append(0, data)
    assert fut.submitted_block == 0
    assert fut.result(timeout=10) == 0
    assert np.array_equal(sync_arr.read_extent(0, 0, 24, np.int32),
                          async_arr.read_extent(0, 0, 24, np.int32))


def test_array_submit_read_surfaces_member_failure():
    devs = [make_device(n_blocks=16, read_us_per_block=5.0) for _ in range(2)]
    arr = StripedZoneArray(devs, stripe_blocks=4)
    arr.zone_append(0, typed_blocks(16, seed=22))
    arr.set_offline(0, device=1)
    with pytest.raises(Exception):
        arr.submit_read(0, 0, 16).result(timeout=10)


# ------------------------------------------------------------- RingReader

def test_ring_reader_sequential_contract_and_service_accounting():
    dev = make_device(read_us_per_block=50.0)
    data = typed_blocks(8, seed=23)
    dev.zone_append(0, data)
    with RingReader(lambda p: dev.submit_read(0, p, 1), 8, depth=3) as reader:
        for p in range(8):
            got = np.asarray(reader(p)).view(np.int32)
            assert np.array_equal(got, data[p * 1024:(p + 1) * 1024])
    assert reader.read_seconds > 0.0
    with RingReader(lambda p: dev.submit_read(0, p, 1), 8, depth=2) as reader:
        reader(0)
        with pytest.raises(ValueError, match="sequential"):
            reader(2)


# ----------------------------------------------- offload tiers, bit-identical

@pytest.mark.parametrize("tier", [CsdTier.INTERP, CsdTier.JIT, CsdTier.KERNEL])
def test_offload_tiers_bit_identical_with_and_without_emulation(tier):
    """Acceptance: reactor-backed reads feed every tier the exact bytes the
    synchronous (non-emulated, inline-completion) path feeds it."""
    data = typed_blocks(16, seed=30)
    program = filter_count("int32", "gt", 0)
    results = []
    for read_us in (0.0, 25.0):    # inline completions vs reactor-timed
        dev = make_device(n_blocks=16, read_us_per_block=read_us)
        dev.zone_append(0, data)
        csd = NvmCsd(dev)
        got, stats = csd.run_and_fetch(program, 0, tier=tier)
        results.append(int(got))
    assert results[0] == results[1] == int(run_oracle(program, data))


def test_scheduler_offload_identical_across_emulation_modes():
    data = typed_blocks(64, seed=31)
    program = filter_count("int32", "le", 100)
    results = []
    for read_us in (0.0, 5.0):
        devs = [make_device(n_blocks=32, read_us_per_block=read_us)
                for _ in range(4)]
        arr = StripedZoneArray(devs, stripe_blocks=4)
        arr.zone_append(0, data)
        with OffloadScheduler(arr) as sched:
            got, stats = sched.run_and_fetch(program, 0)
        results.append(int(got))
    assert results[0] == results[1] == int(run_oracle(program, data))


# ------------------------------------------------------- raw I/O on the queues

def test_scheduler_raw_io_commands_roundtrip():
    devs = [make_device(n_blocks=32, read_us_per_block=10.0,
                        append_us_per_block=10.0) for _ in range(2)]
    arr = StripedZoneArray(devs, stripe_blocks=4)
    data = typed_blocks(16, seed=40)
    with OffloadScheduler(arr) as sched:
        sched.register_tenant("ckpt", weight=2)
        cid_a = sched.submit_io("append", 1, data=data, tenant="ckpt",
                                _watch=True)
        sched.drain()
        comp_a = sched.wait(cid_a, timeout=10)
        assert comp_a.ok and comp_a.value == 0
        cid_r = sched.submit_io("read", 1, n_blocks=16, tenant="ckpt",
                                _watch=True)
        sched.drain()
        comp_r = sched.wait(cid_r, timeout=10)
        assert comp_r.ok
        assert np.array_equal(np.asarray(comp_r.value).view(np.int32), data)
        # raw I/O never clobbers the part-i last-offload-result register
        with pytest.raises(RuntimeError):
            sched.nvm_cmd_bpf_result()


def test_raw_io_completion_lands_on_tenant_cq():
    devs = [make_device(n_blocks=32, append_us_per_block=10.0)
            for _ in range(2)]
    arr = StripedZoneArray(devs, stripe_blocks=4)
    with OffloadScheduler(arr) as sched:
        sched.register_tenant("ckpt")
        fired = threading.Event()
        sched.submit_io("append", 1, data=typed_blocks(8), tenant="ckpt",
                        on_complete=lambda c: fired.set())
        sched.drain()
        assert fired.wait(timeout=10)
        comp = sched.queue_pair("ckpt").cq.pop(timeout=10)
        assert comp is not None and comp.ok


# --------------------------------------------------------- async checkpoints

def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": rng.standard_normal((64, 64)).astype(np.float32),
        "b": rng.integers(-5, 5, 256, dtype=np.int64),
    }


def _like():
    return {"w": np.zeros((64, 64), np.float32), "b": np.zeros(256, np.int64)}


def test_checkpoint_save_async_commit_and_restore_async():
    dev = make_device(n_blocks=64, num_zones=6,
                      read_us_per_block=5.0, append_us_per_block=5.0)
    store = ZonedCheckpointStore(device=dev, keep=2)
    tree = _tree(1)
    ticket = store.save_async(7, tree)
    manifest = ticket.result(timeout=30)
    assert manifest["step"] == 7 and store.latest_step() == 7
    # every payload entry's block came from its append COMPLETION
    assert all(e["block"] >= 0 for e in manifest["entries"])
    got = store.restore_async(like=_like()).result(timeout=30)
    assert np.array_equal(got["w"], tree["w"])
    assert np.array_equal(got["b"], tree["b"])


def test_checkpoint_async_matches_sync_restore_bitwise():
    dev = make_device(n_blocks=64, num_zones=6, append_us_per_block=2.0)
    store = ZonedCheckpointStore(device=dev, keep=2)
    tree = _tree(2)
    store.save(1, tree)
    sync = store.restore(like=_like())
    async_ = store.restore_async(like=_like()).result(timeout=30)
    assert np.array_equal(np.asarray(sync["w"]), np.asarray(async_["w"]))
    assert np.array_equal(np.asarray(sync["b"]), np.asarray(async_["b"]))


def test_striped_checkpoint_restore_bit_identical_async_vs_sync(tmp_path):
    """Acceptance: striped restore through the ring == synchronous restore,
    and an async-saved striped checkpoint survives a reopen."""
    store = ZonedCheckpointStore.striped(tmp_path, num_devices=3,
                                         num_zones=6,
                                         member_zone_bytes=64 * BLOCK,
                                         stripe_blocks=4)
    tree = _tree(3)
    store.save_async(5, tree).result(timeout=30)
    store.flush()
    sync = store.restore(like=_like())
    async_ = store.restore_async(like=_like()).result(timeout=30)
    assert np.array_equal(np.asarray(sync["w"]), np.asarray(async_["w"]))
    assert np.array_equal(np.asarray(sync["b"]), np.asarray(async_["b"]))
    reopened = ZonedCheckpointStore.striped(tmp_path)
    got = reopened.restore(like=_like())
    assert np.array_equal(np.asarray(got["w"]), tree["w"])


def test_checkpoint_rides_scheduler_queues_overlapping_offloads():
    """Checkpoint save through the submission queues while offload traffic
    flows: both finish, results correct, checkpoint tenant CQ sees entries."""
    devs = [make_device(n_blocks=128, num_zones=8, read_us_per_block=3.0,
                        append_us_per_block=3.0) for _ in range(2)]
    arr = StripedZoneArray(devs, stripe_blocks=4)
    data = typed_blocks(64, seed=50)
    arr.zone_append(7, data)
    arr.finish_zone(7)
    program = filter_count("int32", "gt", 0)
    expected = int(run_oracle(program, data))
    with OffloadScheduler(arr) as sched:
        store = ZonedCheckpointStore(device=arr, keep=4, scheduler=sched)
        sched.start()
        tree = _tree(4)
        cids = [sched.submit(program, 7, _watch=True) for _ in range(3)]
        ticket = store.save_async(9, tree)
        comps = [sched.wait(c, timeout=60) for c in cids]
        manifest = ticket.result(timeout=60)
        assert all(c.ok and int(c.value) == expected for c in comps)
        assert manifest["step"] == 9
        got = store.restore(like=_like())
        assert np.array_equal(got["w"], tree["w"])
        assert len(sched.queue_pair("checkpoint").cq) > 0


def test_checkpoint_manifest_zone_full_fails_ticket_not_hangs():
    """A full manifest zone must surface as a ticket error (the sync path
    used to raise ZoneFullError loudly) — never a forever-pending ticket."""
    dev = make_device(n_blocks=4, num_zones=4)   # tiny 4-block manifest zone
    store = ZonedCheckpointStore(device=dev, keep=99)
    tree = {"x": np.arange(64, dtype=np.int64)}
    with pytest.raises(Exception):
        for step in range(64):   # manifest zone fills after a few commits
            store.save(step, tree)
    assert store.latest_step() is not None       # earlier saves committed


def test_checkpoint_more_leaves_than_queue_depth_backpressures():
    """Scheduler-routed save with leaves >> SQ depth must throttle via
    backpressure, not raise QueueFullError mid-save."""
    devs = [make_device(n_blocks=256, num_zones=8, append_us_per_block=1.0)
            for _ in range(2)]
    arr = StripedZoneArray(devs, stripe_blocks=4)
    with OffloadScheduler(arr, queue_depth=8) as sched:
        store = ZonedCheckpointStore(device=arr, keep=2, scheduler=sched)
        tree = {f"leaf{i}": np.arange(1024, dtype=np.int32)
                for i in range(40)}              # 40 appends vs depth-8 SQ
        manifest = store.save_async(1, tree).result(timeout=60)
        assert len(manifest["entries"]) == 40
        got = store.restore(like=tree)
        assert all(np.array_equal(got[k], tree[k]) for k in tree)


def test_gc_never_resets_zones_of_inflight_save():
    """gc() must skip zones an uncommitted save_async is writing — their
    manifest does not exist yet, so the live-set alone cannot protect them."""
    dev = make_device(n_blocks=64, num_zones=3,      # manifest + 2 payload
                      append_us_per_block=200.0)     # keep the save in flight
    store = ZonedCheckpointStore(device=dev, keep=1)
    small = {"x": np.arange(1024, dtype=np.int32)}   # 1 block per save
    store.save(0, small)
    store.save(1, small)                             # manifests now > keep
    ticket = store.save_async(2, small)              # ~13ms of append left
    assert not ticket.done()
    store.gc()                                       # must skip save-2's zone
    manifest = ticket.result(timeout=30)
    got = store.restore(step=2, like=small)
    assert np.array_equal(got["x"], small["x"])


def test_overlapping_saves_commit_in_step_order():
    """A small step-2 save can retire before a fat step-1 save; latest_step()
    must still be the newest STEP, live and across reopen."""
    dev = make_device(n_blocks=256, num_zones=6, append_us_per_block=50.0)
    store = ZonedCheckpointStore(device=dev, keep=4)
    big = {"x": np.arange(64 * 1024, dtype=np.int32)}    # 64 blocks: ~3.2ms
    small = {"x": np.arange(1024, dtype=np.int32)}       # 1 block: ~50us
    t1 = store.save_async(1, big)
    t2 = store.save_async(2, small)
    m2 = t2.result(timeout=30)
    m1 = t1.result(timeout=30)
    assert m1["step"] == 1 and m2["step"] == 2
    assert store.steps() == [1, 2]                   # step order, not landing
    assert store.latest_step() == 2
    got = store.restore(like=small)                  # step=None -> newest step
    assert np.array_equal(got["x"], small["x"])


def test_checkpoint_copy_accounting():
    dev = make_device(n_blocks=64, num_zones=4)
    store = ZonedCheckpointStore(device=dev, keep=2)
    tree = _tree(5)
    payload = sum(np.asarray(v).nbytes for v in tree.values())
    c0 = store.stats["bytes_copied"]
    store.save(0, tree)
    assert store.stats["bytes_copied"] - c0 == payload  # serialization only
    c0 = store.stats["bytes_copied"]
    v0 = store.stats["bytes_viewed"]
    store.restore(like=_like())
    assert store.stats["bytes_copied"] - c0 == payload  # ONE copy per leaf
    assert store.stats["bytes_viewed"] - v0 >= payload  # extents arrive as views


def test_datastore_copy_accounting():
    from repro.data.pipeline import ZoneDataStore
    dev = make_device(n_blocks=64)
    store = ZoneDataStore(dev, seq_len=31)
    toks = np.arange(8 * 31, dtype=np.int32).reshape(8, 31)
    store.append_records(0, toks)
    assert store.stats["bytes_copied"] > 0          # staging copy counted
    assert store.stats["bytes_copied"] % dev.block_bytes == 0
