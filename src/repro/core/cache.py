"""Shared cache of compiled offload executables.

The paper reports "JIT time" as a first-class offload statistic because
compilation is the dominant fixed cost of a fresh offload. Before this module
every consumer kept its own ad-hoc dict — one per :class:`~repro.core.csd.NvmCsd`,
two per :class:`~repro.array.scheduler.OffloadScheduler` (single + vmapped) and
nothing at all for the Pallas tier, which re-traced on every call. The
:class:`CompiledProgramCache` promotes them into one bounded, thread-safe LRU
keyed by ``(tier kind, program, geometry)``:

  * programs are frozen dataclasses, so the program itself is the signature;
  * geometry (pages, elements per page, chunk batch) pins the compiled shape;
  * the tier kind ("jit" / "jit_batched" / "kernel" / "kernel_batched")
    separates executables with identical shapes but different backends.

Builds are compile-once per key but do NOT hold the cache-wide lock: the
first thread to miss a key builds it while only same-key racers wait (they
block on a per-key event and then count as hits with zero compile time —
nobody double-counts ``jit_seconds``); lookups for other keys proceed
untouched, so one multi-second XLA compile cannot stall every device worker
sharing the process-wide cache. Hit/miss/eviction counts are host-visible
(surfaced per-offload in ``OffloadStats`` and in aggregate via
:meth:`CompiledProgramCache.stats`).
"""
from __future__ import annotations

import threading
import weakref
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Hashable, Optional, Tuple

from repro.telemetry.metrics import registry as _registry

__all__ = ["CompiledProgramCache", "CacheStats", "default_cache",
           "DEFAULT_CACHE_CAPACITY"]

DEFAULT_CACHE_CAPACITY = 128


@dataclass(frozen=True)
class CacheStats:
    """Point-in-time cache counters (cumulative since construction)."""

    hits: int
    misses: int
    evictions: int
    size: int
    capacity: int

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


@dataclass
class _Build:
    """Rendezvous for threads racing on one uncompiled key."""

    done: threading.Event = field(default_factory=threading.Event)
    entry: object = None


class CompiledProgramCache:
    """Bounded, thread-safe LRU of compiled offload executables."""

    # every live cache, so ONE metrics collector can aggregate them all
    # (weak: a dropped cache must not be pinned by its own telemetry)
    _instances: "weakref.WeakSet[CompiledProgramCache]" = weakref.WeakSet()
    _instances_lock = threading.Lock()

    def __init__(self, capacity: int = DEFAULT_CACHE_CAPACITY):
        if capacity <= 0:
            raise ValueError("cache capacity must be positive")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._entries: OrderedDict[Hashable, object] = OrderedDict()
        self._building: dict[Hashable, _Build] = {}
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        with CompiledProgramCache._instances_lock:
            CompiledProgramCache._instances.add(self)

    def get_or_build(self, key: Hashable,
                     builder: Callable[[], object]) -> Tuple[object, float, bool]:
        """Return ``(executable, compile_seconds, hit)`` for ``key``.

        ``compile_seconds`` is 0.0 on a hit; on a miss ``builder()`` runs
        OUTSIDE the cache lock (lookups for other keys proceed during the
        compile) while same-key racers wait and then report a hit. ``builder``
        must return an object with a ``compile_seconds`` attribute (e.g.
        :class:`~repro.core.vm.JittedProgram`). If a build fails, its waiters
        retry (one of them becomes the next builder).
        """
        while True:
            with self._lock:
                entry = self._entries.get(key)
                if entry is not None:
                    self._entries.move_to_end(key)
                    self._hits += 1
                    return entry, 0.0, True
                build = self._building.get(key)
                am_builder = build is None
                if am_builder:
                    build = _Build()
                    self._building[key] = build
            if am_builder:
                try:
                    entry = builder()
                except BaseException:
                    with self._lock:
                        self._building.pop(key, None)
                    build.done.set()     # waiters retry (entry stays None)
                    raise
                with self._lock:
                    self._misses += 1
                    self._entries[key] = entry
                    while len(self._entries) > self.capacity:
                        self._entries.popitem(last=False)
                        self._evictions += 1
                    self._building.pop(key, None)
                build.entry = entry
                build.done.set()
                return entry, float(getattr(entry, "compile_seconds", 0.0)), False
            build.done.wait()
            if build.entry is not None:
                with self._lock:
                    self._hits += 1
                return build.entry, 0.0, True
            # builder failed: loop; one waiter becomes the next builder

    def __contains__(self, key: Hashable) -> bool:
        with self._lock:
            return key in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> CacheStats:
        with self._lock:
            return CacheStats(self._hits, self._misses, self._evictions,
                              len(self._entries), self.capacity)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()


def _collect_cache_stats() -> dict:
    """Aggregate hit/miss/eviction/size over every LIVE compile cache — the
    ``compile_cache.*`` series of the global metrics snapshot (the ISSUE's
    "one snapshot shows the whole offload picture")."""
    hits = misses = evictions = size = 0
    with CompiledProgramCache._instances_lock:
        caches = list(CompiledProgramCache._instances)
    for c in caches:
        s = c.stats()
        hits += s.hits
        misses += s.misses
        evictions += s.evictions
        size += s.size
    total = hits + misses
    return {
        "hits": hits,
        "misses": misses,
        "evictions": evictions,
        "size": size,
        "hit_rate": hits / total if total else 0.0,
        "live_caches": len(caches),
    }


_registry().register_collector("compile_cache", _collect_cache_stats)

_default: Optional[CompiledProgramCache] = None
_default_lock = threading.Lock()


def default_cache() -> CompiledProgramCache:
    """The process-wide cache: pass it to every ``NvmCsd``/``OffloadScheduler``
    that should share compiled executables (the multi-device deployment
    default — programs are device-agnostic, so reuse is maximal)."""
    global _default
    with _default_lock:
        if _default is None:
            _default = CompiledProgramCache()
        return _default
