"""Analytics offload: the paper's data-processing use case on record data.

A zone holds fixed-stride records [store_id, amount, status, pad...]; all
aggregation runs device-side through verified programs — the host receives
scalars and histograms, never the table. This is the YourSQL/Ibex-style
query pushdown the paper positions ZCSD to prototype.

    PYTHONPATH=src python examples/csd_pushdown_analytics.py
"""
import numpy as np

from repro.core import CsdTier, NvmCsd, field_reduce
from repro.core.programs import Instruction, OpCode, Program, select_records
from repro.zns import ZonedDevice

STRIDE = 8           # record: [store_id, amount, status, 5 x pad]
N_RECORDS = 128 * 1024


def main():
    dev = ZonedDevice(num_zones=1, zone_bytes=8 * 1024 * 1024,
                      block_bytes=4096)
    rng = np.random.default_rng(7)
    recs = np.zeros((N_RECORDS, STRIDE), np.int32)
    recs[:, 0] = rng.integers(0, 50, N_RECORDS)          # store_id
    recs[:, 1] = rng.integers(1, 10_000, N_RECORDS)      # amount (cents)
    recs[:, 2] = rng.integers(0, 3, N_RECORDS)           # status (2 = refund)
    dev.zone_append(0, recs)
    csd = NvmCsd(dev)
    table_mb = recs.nbytes / 1e6

    # Q1: SELECT SUM(amount) — device-side field reduce
    q1 = field_reduce("int32", STRIDE, 1, kind="sum")
    st = csd.nvm_cmd_bpf_run(q1, 0, tier=CsdTier.JIT)
    total = int(csd.nvm_cmd_bpf_result())
    assert total == int(recs[:, 1].sum())
    print(f"Q1 SUM(amount) = {total}   "
          f"[{st.bytes_returned} B back vs {table_mb:.1f} MB table; "
          f"saved {st.movement_saved_bytes / 1e6:.1f} MB]")

    # Q2: SELECT COUNT(*) WHERE status == 2
    q2 = Program("int32", (Instruction(OpCode.FIELD, (STRIDE, 2)),
                           Instruction(OpCode.CMP_EQ, 2),
                           Instruction(OpCode.RED_COUNT)), name="refunds")
    st = csd.nvm_cmd_bpf_run(q2, 0, tier=CsdTier.JIT)
    refunds = int(csd.nvm_cmd_bpf_result())
    assert refunds == int((recs[:, 2] == 2).sum())
    print(f"Q2 COUNT(refunds) = {refunds}   "
          f"[saved {st.movement_saved_bytes / 1e6:.1f} MB]")

    # Q3: histogram of amounts (device-side GROUP BY bucket)
    from repro.core import histogram
    q3 = Program("int32", (Instruction(OpCode.FIELD, (STRIDE, 1)),
                           Instruction(OpCode.RED_HIST, (0, 10_000, 10))),
                 name="amount_hist")
    st = csd.nvm_cmd_bpf_run(q3, 0, tier=CsdTier.JIT)
    hist = np.asarray(csd.nvm_cmd_bpf_result())
    print(f"Q3 amount histogram: {hist.tolist()}   "
          f"[{st.bytes_returned} B back]")

    # Q4: SELECT * WHERE amount > 9900 — record-granular pushdown select
    q4 = select_records("int32", STRIDE, 1, "gt", 9900, capacity=4096)
    st = csd.nvm_cmd_bpf_run(q4, 0, tier=CsdTier.JIT)
    rows, count = csd.nvm_cmd_bpf_result()
    rows = np.asarray(rows)[: int(count)]
    want = recs[recs[:, 1] > 9900]
    np.testing.assert_array_equal(rows, want)
    print(f"Q4 big-ticket rows: {int(count)} records "
          f"({rows.nbytes / 1e3:.1f} kB back vs {table_mb:.1f} MB table; "
          f"{st.reduction_factor:.0f}x reduction)")

    # interpreter tier agrees (the safety-first execution mode)
    csd.nvm_cmd_bpf_run(q2, 0, tier=CsdTier.INTERP)
    assert int(csd.nvm_cmd_bpf_result()) == refunds
    print("interp tier agrees with JIT tier — verified end to end")


if __name__ == "__main__":
    main()
