# CI entry points. `make ci` is what the tier-1 gate runs: the full pytest
# suite plus a fast benchmark smoke (filter + array scaling).
PYTHONPATH := src:$(PYTHONPATH)
export PYTHONPATH

.PHONY: test smoke ci bench

test:
	python -m pytest -x -q

smoke:
	python benchmarks/run.py --only filter,array

ci: test smoke

bench:
	python benchmarks/run.py
