"""Toolchain overheads (paper §4 'performance of the toolchain').

Measures, as a function of program length: verifier latency, XLA JIT compile
latency (the paper's 152 us uBPF figure is the analogue), and interpreter
dispatch overhead per instruction."""
from __future__ import annotations

import time

import numpy as np

from repro.core import CsdTier, NvmCsd
from repro.core.programs import Instruction, OpCode, Program
from repro.core.verifier import verify_program
from repro.core.vm import jit_program
from repro.zns import ZonedDevice


def chain_program(n_alu: int) -> Program:
    insns = tuple(Instruction(OpCode.ADD, 1) for _ in range(n_alu)) + (
        Instruction(OpCode.CMP_GT, 0), Instruction(OpCode.RED_COUNT))
    return Program("int32", insns, name=f"chain{n_alu}")


def main() -> list[str]:
    rows = []
    n_pages, page_elems = 256, 1024
    dev = ZonedDevice(num_zones=1, zone_bytes=n_pages * 4096, block_bytes=4096)
    rng = np.random.default_rng(0)
    dev.zone_append(0, rng.integers(0, 2**31, n_pages * page_elems,
                                    dtype=np.int32))
    csd = NvmCsd(dev)
    for n_alu in (0, 4, 16, 64):
        prog = chain_program(n_alu)
        t = time.perf_counter()
        for _ in range(50):
            verify_program(prog, page_elems=page_elems, n_pages=n_pages)
        verify_us = (time.perf_counter() - t) / 50 * 1e6

        t = time.perf_counter()
        jp = jit_program(prog, n_pages, page_elems)
        jit_us = (time.perf_counter() - t) * 1e6

        s_int = csd.nvm_cmd_bpf_run(prog, 0, tier=CsdTier.INTERP)
        s_jit = csd.nvm_cmd_bpf_run(prog, 0, tier=CsdTier.JIT)
        interp_per_insn_ns = s_int.exec_seconds / s_int.insns_executed * 1e9
        rows.append(
            f"toolchain_n{n_alu + 2},{jit_us:.0f},"
            f"verify_us={verify_us:.1f};interp_exec_us={s_int.exec_seconds * 1e6:.0f};"
            f"jit_exec_us={s_jit.exec_seconds * 1e6:.0f};"
            f"interp_ns_per_insn={interp_per_insn_ns:.0f}"
        )
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
