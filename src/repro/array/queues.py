"""NVMe-style submission/completion queue pairs for offload commands.

Mirrors the NVMe queueing model the paper's device sits behind: per-tenant
submission queues (SQs) with bounded depth, one completion queue (CQ) per
pair, and a weighted round-robin arbiter (the NVMe 'WRR with urgent priority'
arbitration mechanism, minus the urgent class) that decides which SQ the
device doorbell services next.

Backpressure is explicit: a full SQ either rejects the command
(``QueueFullError``, the NVMe 'queue full' status) or blocks the submitter
until the arbiter drains a slot, so one chatty tenant cannot starve the
device of queue slots.

Commands carry *verified* programs: the scheduler verifies before enqueue, so
everything past the SQ is admitted work (the same contract the paper's
verifier gives the single device). Since the completion-ring device model,
a command may instead carry a RAW I/O operation (``io_op`` = ``"read"`` /
``"append"``): the dispatcher forwards it to the array's submit path without
blocking and the completion arrives from the reactor — this is how checkpoint
save/restore rides the same queues (and the same WRR arbitration) as offload
traffic instead of issuing synchronous array calls.
"""
from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Optional, Sequence

import numpy as np

from repro.core.programs import Program
from repro.telemetry.events import Severity as _Sev, publish as _publish_event
from repro.telemetry.metrics import registry as _registry
from repro.zns.ring import CompletionRing

# admission waits / WRR grant latencies above this are published as events
# (stall / starvation) on top of the always-on histograms — the operator
# signal that one tenant's backpressure turned pathological
STALL_EVENT_SECONDS = 0.25

__all__ = [
    "QueueFullError",
    "OffloadCommand",
    "Completion",
    "SubmissionQueue",
    "CompletionQueue",
    "QueuePair",
    "WeightedRoundRobinArbiter",
]


class QueueFullError(Exception):
    """Submission queue at depth limit (NVMe 'queue full' status)."""


_cmd_ids = itertools.count(1)


@dataclass
class OffloadCommand:
    """One verified submission (an NVMe command capsule analogue).

    Two shapes share the capsule: a verified offload (``program`` set,
    ``io_op`` None) executed by the scheduler's fan-out engine, or a raw I/O
    command (``program`` None, ``io_op`` = ``"read"``/``"append"``) the
    dispatcher forwards to the device's completion ring without blocking —
    ``data`` carries the append payload. ``on_complete`` (if set) receives
    the full :class:`Completion` when the command finishes, whichever thread
    retires it — the hook checkpoint tickets ride on.
    """

    program: Optional[Program]
    zone_id: int
    block_off: int
    n_blocks: Optional[int]
    tier: Optional[str]
    tenant: str = "default"
    cmd_id: int = field(default_factory=lambda: next(_cmd_ids))
    insns_verified: int = 0
    io_op: Optional[str] = None
    data: Optional[np.ndarray] = None
    # raw I/O only: target ONE array member instead of the logical array —
    # how rebuild/scrub traffic reaches an individual device while still
    # riding the tenant SQs and WRR arbitration
    member: Optional[int] = None
    on_complete: Optional[Callable[["Completion"], None]] = None
    # monotonic instant the command entered its SQ; the arbiter derives WRR
    # grant latency (SQ residency) from it
    submitted_at: float = 0.0


@dataclass
class Completion:
    """CQ entry: result (or error) + the aggregated stats for the command."""

    cmd_id: int
    tenant: str
    value: Any = None
    stats: Any = None
    error: Optional[BaseException] = None

    @property
    def ok(self) -> bool:
        return self.error is None


class SubmissionQueue:
    """Bounded FIFO of offload commands for one tenant."""

    def __init__(self, tenant: str, *, depth: int = 32, weight: int = 1):
        if depth <= 0:
            raise ValueError("queue depth must be positive")
        if weight <= 0:
            raise ValueError("arbitration weight must be positive")
        self.tenant = tenant
        self.depth = depth
        self.weight = weight
        self._q: deque[OffloadCommand] = deque()
        self._cond = threading.Condition()
        self.submitted = 0
        self.rejected = 0

    def submit(self, cmd: OffloadCommand, *, block: bool = False,
               timeout: Optional[float] = None) -> None:
        t0 = time.monotonic()
        deadline = None if timeout is None else t0 + timeout
        try:
            with self._cond:
                if len(self._q) >= self.depth and not block:
                    self.rejected += 1
                    raise QueueFullError(
                        f"SQ '{self.tenant}' full (depth={self.depth})")
                while len(self._q) >= self.depth:
                    # honour the TOTAL deadline across wakeups (a woken
                    # submitter may lose its slot to a rival and wait again)
                    remaining = None if deadline is None \
                        else deadline - time.monotonic()
                    if (remaining is not None and remaining <= 0) or \
                            not self._cond.wait(timeout=remaining):
                        self.rejected += 1
                        raise QueueFullError(
                            f"SQ '{self.tenant}' full after {timeout}s "
                            f"(depth={self.depth})")
                now = time.monotonic()
                cmd.submitted_at = now
                self._q.append(cmd)
                self.submitted += 1
        except QueueFullError as e:
            # outside the condition lock: event subscribers may themselves
            # touch the queues
            _publish_event(
                "sq.reject", severity=_Sev.WARNING, message=str(e),
                tenant=self.tenant, depth=self.depth)
            raise
        # admission wait = backpressure the submitter ate before its slot
        # opened (zero on the uncontended path); tenant names are a bounded
        # set, so per-tenant series live on the global registry
        wait = now - t0
        _registry().histogram(
            f"tenant.{self.tenant}.sq_admission_wait_seconds").observe(wait)
        if wait > STALL_EVENT_SECONDS:
            _publish_event(
                "sq.stall", severity=_Sev.WARNING,
                message=f"SQ '{self.tenant}' admission stalled "
                        f"{wait * 1e3:.0f}ms (depth={self.depth})",
                tenant=self.tenant, wait_s=wait, depth=self.depth)

    def pop(self) -> Optional[OffloadCommand]:
        with self._cond:
            if not self._q:
                return None
            cmd = self._q.popleft()
            self._cond.notify()  # free a slot for a blocked submitter
            return cmd

    def __len__(self) -> int:
        with self._cond:
            return len(self._q)


class CompletionQueue(CompletionRing):
    """One tenant's fixed-depth ring of command completions (an NVMe CQ is a
    fixed-size ring: a host that does not keep up loses the oldest entries,
    counted in ``dropped``, rather than growing device memory without bound).
    The overwrite/accounting mechanics are the device layer's
    :class:`~repro.zns.ring.CompletionRing` — one implementation for both
    the raw-transfer ring and the per-tenant command CQ."""

    def __init__(self, tenant: str, *, depth: int = 256):
        super().__init__(depth)
        self.tenant = tenant


@dataclass
class QueuePair:
    """One tenant's SQ/CQ pair (NVMe I/O queue pair analogue)."""

    sq: SubmissionQueue
    cq: CompletionQueue

    @property
    def tenant(self) -> str:
        return self.sq.tenant


class WeightedRoundRobinArbiter:
    """NVMe-style weighted round-robin over submission queues.

    Each round grants SQ ``i`` up to ``weight_i`` command slots; queues are
    serviced in order within the round, and empty queues forfeit their
    remaining credit. With every queue kept full, the dispatch mix converges
    to the weight ratio while staying work-conserving when queues run dry.
    """

    def __init__(self, pairs: Sequence[QueuePair] = ()):
        self._pairs: list[QueuePair] = list(pairs)
        self._lock = threading.Lock()
        self._credits: list[int] = [p.sq.weight for p in self._pairs]
        self._pos = 0

    def add(self, pair: QueuePair) -> None:
        with self._lock:
            self._pairs.append(pair)
            self._credits.append(pair.sq.weight)

    @property
    def pairs(self) -> list[QueuePair]:
        return list(self._pairs)

    def _refresh(self) -> None:
        self._credits = [p.sq.weight for p in self._pairs]

    def next_command(self) -> Optional[tuple[OffloadCommand, QueuePair]]:
        """Pop the next command per WRR policy, or None if every SQ is empty."""
        with self._lock:
            granted = self._next_locked()
        if granted is None:
            return None
        cmd, pair = granted
        # WRR grant latency: how long the command sat in its SQ before
        # arbitration granted it a slot; pathological residency (a starved
        # low-weight tenant behind heavy rivals) also surfaces as an event.
        # Metrics + events run outside the arbiter lock.
        wait = time.monotonic() - cmd.submitted_at
        _registry().histogram(
            f"tenant.{pair.tenant}.wrr_grant_seconds").observe(wait)
        if wait > STALL_EVENT_SECONDS:
            _publish_event(
                "wrr.starvation", severity=_Sev.WARNING,
                message=f"tenant '{pair.tenant}' command waited "
                        f"{wait * 1e3:.0f}ms for a WRR grant",
                tenant=pair.tenant, wait_s=wait)
        return cmd, pair

    def _next_locked(self) -> Optional[tuple[OffloadCommand, QueuePair]]:
        if not self._pairs:
            return None
        n = len(self._pairs)
        # at most two passes: one with current credits, one after refresh
        for _ in range(2):
            scanned = 0
            while scanned < n:
                i = self._pos
                pair, credit = self._pairs[i], self._credits[i]
                if credit > 0:
                    cmd = pair.sq.pop()
                    if cmd is not None:
                        self._credits[i] -= 1
                        if self._credits[i] == 0:
                            self._pos = (i + 1) % n
                        return cmd, pair
                # empty queue forfeits its credit for this round
                self._credits[i] = 0
                self._pos = (i + 1) % n
                scanned += 1
            self._refresh()
        return None
