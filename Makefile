# CI entry points. `make ci` is what the tier-1 gate runs: the full pytest
# suite plus a fast benchmark smoke (filter + array scaling + hot-path
# accounting + async completion-ring scaling) that emits the machine-readable
# BENCH_hotpath.json and BENCH_async.json.
PYTHONPATH := src:$(PYTHONPATH)
export PYTHONPATH

.PHONY: test smoke ci bench bench-smoke

test:
	python -m pytest -x -q

smoke:
	python benchmarks/run.py --only filter,array,hotpath,async --json

# hot-path regression tripwire: the CI-size suites must fit the wall-clock
# budget (measured ~10s on 2 cores incl. compiles; ~9x headroom so only a
# real regression, not scheduler noise, trips it). The async suite asserts
# its own queue-depth tripwire: depth-8 throughput must exceed depth-1 (and
# beat 4 thread-blocking workers), and the overlapped checkpoint save must
# beat the serialized sequence.
bench-smoke:
	python benchmarks/run.py --only filter,array,async --budget 90

ci: test smoke

bench:
	python benchmarks/run.py
