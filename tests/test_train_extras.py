"""Optimizer, gradient compression, LR schedule, sharding-rule invariants —
property-based where the invariant is algebraic."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.train.optimizer import (
    AdamWHyper, adamw_update, compress_int8, cosine_lr, decompress_int8,
)
from repro.sharding.rules import Rules, TRAIN_RULES, logical_to_spec, rules_for


# ----------------------------------------------------------------- adamw

def test_adamw_decreases_quadratic_loss():
    h = AdamWHyper(lr=0.1, warmup_steps=0, total_steps=1000, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    m = {"w": jnp.zeros(3)}
    v = {"w": jnp.zeros(3)}
    step = jnp.asarray(0)
    for i in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw ||w||^2
        params, m, v, _ = adamw_update(params, grads, m, v,
                                       jnp.asarray(i), h)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_adamw_grad_clip_applies():
    h = AdamWHyper(lr=1e-3, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.ones(4)}
    big = {"w": jnp.full(4, 1e6)}
    _, _, _, metrics = adamw_update(params, big, {"w": jnp.zeros(4)},
                                    {"w": jnp.zeros(4)}, jnp.asarray(0), h)
    assert float(metrics["grad_norm"]) > 1e5     # reported pre-clip


@given(step=st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_cosine_lr_bounds(step):
    h = AdamWHyper(lr=3e-4, warmup_steps=100, total_steps=10_000)
    lr = float(cosine_lr(h, jnp.asarray(step, jnp.float32)))
    assert 0.0 <= lr <= h.lr + 1e-9


# ------------------------------------------------------- int8 compression

@given(seed=st.integers(0, 2**16), scale=st.floats(1e-3, 1e3))
@settings(max_examples=30, deadline=None)
def test_int8_error_feedback_contract(seed, scale):
    """decompress(compress(g)) + err' == g + err (no information lost)."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.standard_normal(256) * scale, jnp.float32)
    err = jnp.asarray(rng.standard_normal(256) * scale * 0.01, jnp.float32)
    q, s, new_err = compress_int8(g, err)
    assert q.dtype == jnp.int8
    recon = decompress_int8(q, s)
    np.testing.assert_allclose(np.asarray(recon + new_err),
                               np.asarray(g + err), rtol=1e-5, atol=1e-5)


def test_int8_error_feedback_converges():
    """Accumulated error feedback keeps the long-run mean unbiased."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.standard_normal(64), jnp.float32)
    err = jnp.zeros(64)
    total = jnp.zeros(64)
    N = 200
    for _ in range(N):
        q, s, err = compress_int8(g_true, err)
        total = total + decompress_int8(q, s)
    np.testing.assert_allclose(np.asarray(total / N), np.asarray(g_true),
                               atol=2e-2)


def test_compressed_training_still_learns():
    """EF-int8 gradient round-trip in the train step keeps training sane:
    loss trajectory close to the uncompressed run."""
    from repro.configs import get_reduced
    from repro.models.api import make_batch
    from repro.models.params import init_params
    from repro.train.step import TrainHyper, make_train_step, train_state_specs

    cfg = get_reduced("h2o-danube-1.8b")
    batches = [make_batch(cfg, 2, 32, seed=i) for i in range(6)]

    def run(compress):
        hyper = TrainHyper(compress_grads=compress)
        state = init_params(train_state_specs(cfg, hyper),
                            jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, hyper))
        losses = []
        for b in batches:
            state, m = step(state, b)
            losses.append(float(m["loss"]))
        return losses

    plain = run(False)
    comp = run(True)
    assert comp[-1] < comp[0]                       # still learning
    assert abs(comp[-1] - plain[-1]) < 0.25          # close trajectory


# ------------------------------------------------------- sharding rules

def test_logical_to_spec_no_duplicate_axes():
    spec = logical_to_spec(TRAIN_RULES, ("act_batch", "embed"))
    flat = []
    for part in spec:
        if part is None:
            continue
        flat.extend(part if isinstance(part, tuple) else (part,))
    assert len(flat) == len(set(flat)), f"mesh axis reused: {spec}"


@given(dim=st.integers(1, 4096))
@settings(max_examples=50, deadline=None)
def test_divisibility_degradation(dim):
    """Degraded specs always evenly divide the dim."""
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    import jax
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    spec = logical_to_spec(TRAIN_RULES, ("q_heads",), (dim,), mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    part = spec[0]
    if part is not None:
        axes = part if isinstance(part, tuple) else (part,)
        prod = int(np.prod([sizes[a] for a in axes]))
        assert dim % prod == 0


def test_rules_for_decode_kv_fallback():
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    import jax
    from repro.configs import get_config
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    # kv=1 on 2-way model axis -> SP-KV: seq carries the model axis
    cfg = get_config("recurrentgemma-9b")
    r = rules_for("decode", cfg, mesh)
    assert r.get("act_kv_seq") == "model"
    assert r.get("act_kv_heads") is None
    # kv=16 divides -> heads keep the model axis
    cfg2 = get_config("seamless-m4t-large-v2")
    r2 = rules_for("decode", cfg2, mesh)
    assert r2.get("act_kv_seq") is None


def test_rules_for_moe_fine_vs_coarse():
    import os
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    import jax
    from repro.configs import get_config
    if len(jax.devices()) < 8:
        pytest.skip("needs 8 host devices")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    fine = rules_for("train", get_config("deepseek-moe-16b"), mesh)
    assert fine.get("act_groups") == ("data", "model")   # weight-gathering EP
    # grok's 8 experts divide a 2-way axis -> expert-dim EP on this mesh
    coarse = rules_for("train", get_config("grok-1-314b"), mesh)
    assert coarse.get("experts") == "model"
    # ...but NOT a non-dividing axis -> TP-within-expert fallback
    mesh2 = jax.make_mesh((2, 4), ("data", "model"))
    cfg6 = get_config("grok-1-314b").replace(num_experts=6)
    fallback = rules_for("train", cfg6, mesh2)
    assert fallback.get("expert_mlp") == "model"
    assert fallback.get("experts") is None
