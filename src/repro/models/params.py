"""Parameter specification machinery.

A model is described once as a pytree of :class:`ParamSpec` (shape + logical
axis names + initializer). From that single source of truth we derive:

  * ``init_params``      — materialized arrays (smoke tests, examples);
  * ``abstract_params``  — ``jax.ShapeDtypeStruct`` stand-ins (dry-run);
  * ``param_shardings``  — ``NamedSharding`` per leaf, via the logical-axis
    rules in :mod:`repro.sharding.rules` (MaxText-style).

Logical axis names used throughout the model zoo:
  layers, embed, q_heads, kv_heads, head_dim, mlp, vocab,
  experts, expert_mlp, conv, ssm_inner, ssm_state, ssm_heads, ssm_head_dim
(Activation logical axes are prefixed ``act_`` and handled separately.)
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, replace
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ParamSpec", "init_params", "abstract_params", "stack_layer_specs",
           "spec_tree_paths"]


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | fan_in | embed | rglru_a
    dtype: Any = jnp.bfloat16
    init_scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    def with_layers(self, n: int) -> "ParamSpec":
        """Prepend a scanned 'layers' dimension."""
        return replace(self, shape=(n, *self.shape), axes=("layers", *self.axes))


def _init_one(spec: ParamSpec, key) -> jnp.ndarray:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "rglru_a":
        # RG-LRU 'a' parameter: initialized so sigmoid-powered decay starts
        # near 0.9..0.999 (per the Griffin paper)
        u = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        # a = sigmoid(Λ); store Λ
        lam = jnp.log(u ** 2 / (1 - u ** 2))
        return lam.astype(spec.dtype)
    if spec.init == "fan_in":
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        scale = 1.0 / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(spec.dtype)
    # default: trunc-normal-ish
    return (jax.random.normal(key, spec.shape, jnp.float32)
            * spec.init_scale).astype(spec.dtype)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def spec_tree_paths(specs) -> list[tuple[str, ParamSpec]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(specs, is_leaf=_is_spec)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def init_params(specs, key) -> Any:
    """Materialize a ParamSpec tree. Keys are derived from the tree path so
    insertion order never changes initialization (checkpoint stability)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(specs, is_leaf=_is_spec)
    leaves = []
    for path, spec in flat:
        name = jax.tree_util.keystr(path)
        digest = int.from_bytes(
            hashlib.sha256(name.encode()).digest()[:4], "little"
        )
        leaves.append(_init_one(spec, jax.random.fold_in(key, digest)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract_params(specs) -> Any:
    """ShapeDtypeStruct stand-ins — zero allocation, for .lower()/.compile()."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=_is_spec
    )


def stack_layer_specs(layer_specs: Any, num_layers: int) -> Any:
    """Give every spec in a per-layer tree a leading scanned 'layers' dim."""
    return jax.tree.map(
        lambda s: s.with_layers(num_layers), layer_specs, is_leaf=_is_spec
    )
