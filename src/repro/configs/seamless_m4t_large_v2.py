"""seamless-m4t-large-v2 [audio] — 24L d_model=1024 16H (GQA kv=16) d_ff=8192
vocab=256206; encoder-decoder, multimodal. [arXiv:2308.11596; hf]

The speech frontend (w2v-BERT feature extractor) is a STUB per the
assignment: ``input_specs`` supplies precomputed frame embeddings; the
24-layer encoder + 24-layer decoder backbone is implemented in full.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,              # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    norm="layer",
    activation="gelu",
    use_bias=True,
    encoder_seq_factor=1.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=3, encoder_layers=3, d_model=64, num_heads=4,
        num_kv_heads=4, head_dim=16, d_ff=128, vocab_size=512, attn_chunk=32,
    )
