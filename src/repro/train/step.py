"""Training step construction: loss -> grads (with microbatch accumulation)
-> AdamW -> new state. Pure function of (state, batch); jit/pjit-ready.

Gradient accumulation scans over microbatches with f32 accumulators; XLA's
SPMD pass turns the per-microbatch gradient contributions into
reduce-scatters against the FSDP-sharded accumulator, which overlaps with the
next microbatch's compute (latency-hiding scheduler).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import loss_fn, param_specs
from repro.models.config import ModelConfig
from repro.models.params import ParamSpec
from repro.train.optimizer import AdamWHyper, adamw_state_specs, adamw_update

__all__ = ["TrainHyper", "train_state_specs", "make_train_step", "init_state"]


@dataclass(frozen=True)
class TrainHyper:
    adamw: AdamWHyper = field(default_factory=AdamWHyper)
    grad_accum: int = 1
    # error-feedback int8 gradient quantization (opt-in): models DCN-
    # compressed gradient exchange on the pod axis — 4x fewer bytes on the
    # slowest link; the residual re-enters the next step via the `err` state
    compress_grads: bool = False


def train_state_specs(cfg: ModelConfig, hyper: Optional["TrainHyper"] = None
                      ) -> dict:
    ps = param_specs(cfg)
    opt = adamw_state_specs(ps)
    state = {
        "params": ps,
        "m": opt["m"],
        "v": opt["v"],
        "step": ParamSpec((), (), init="zeros", dtype=jnp.int32),
    }
    if hyper is not None and hyper.compress_grads:
        state["err"] = opt["m"]   # same f32/axes tree: the EF residual
    return state


def init_state(cfg: ModelConfig, key, hyper: Optional["TrainHyper"] = None
               ) -> dict:
    from repro.models.params import init_params
    return init_params(train_state_specs(cfg, hyper), key)


def make_train_step(cfg: ModelConfig, hyper: TrainHyper):
    accum = max(hyper.grad_accum, 1)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
        return loss, metrics, grads

    def train_step(state, batch):
        params = state["params"]
        if accum == 1:
            loss, metrics, grads = grads_of(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            def micro(carry, mb):
                acc, loss_sum = carry
                loss, _, g = grads_of(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(jnp.float32), acc, g)
                return (acc, loss_sum + loss), None
            mbs = jax.tree.map(
                lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / accum, gsum)
            loss = loss_sum / accum
            metrics = {}
        new_err = None
        if hyper.compress_grads:
            # quantize the gradient signal through error-feedback int8 (the
            # 4x-compressed DCN exchange); residual re-enters next step
            from repro.train.optimizer import compress_int8, decompress_int8

            def roundtrip(g, e):
                q, s, e2 = compress_int8(g, e)
                return decompress_int8(q, s), e2
            pairs = jax.tree.map(roundtrip, grads, state["err"])
            grads = jax.tree.map(lambda p: p[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
            new_err = jax.tree.map(lambda p: p[1], pairs,
                                   is_leaf=lambda x: isinstance(x, tuple))
        new_p, new_m, new_v, opt_metrics = adamw_update(
            params, grads, state["m"], state["v"], state["step"], hyper.adamw)
        new_state = {"params": new_p, "m": new_m, "v": new_v,
                     "step": state["step"] + 1}
        if new_err is not None:
            new_state["err"] = new_err
        out_metrics = {"loss": loss, **opt_metrics}
        return new_state, out_metrics

    return train_step
