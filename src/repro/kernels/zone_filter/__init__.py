from repro.kernels.zone_filter.ops import (
    KERNELIZABLE_TERMINALS,
    run_program_kernel,
    zone_filter_count,
)

__all__ = ["zone_filter_count", "run_program_kernel", "KERNELIZABLE_TERMINALS"]
