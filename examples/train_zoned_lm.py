"""End-to-end training driver: zone-fed data, pushdown filtering, zoned
checkpoints, fault-tolerant resume.

Trains a small LM (llama-family reduced config) where EVERY substrate is the
ZCSD stack: training records live in ZNS zones with a quality field, the
pipeline pushes quality filtering down to the device tier, checkpoints are
append-only zone writes with manifest commits, and killing/restarting the
script resumes exactly.

    PYTHONPATH=src python examples/train_zoned_lm.py                 # tiny, CPU
    PYTHONPATH=src python examples/train_zoned_lm.py --preset 100m   # ~100M

The synthetic corpus follows a fixed random bigram chain, so the loss has
real structure to learn: it should fall well below ln(vocab) uniform.
"""
import argparse
import time

import numpy as np

from repro.configs import get_reduced
from repro.data import PrefetchLoader, ZoneDataPipeline, ZoneDataStore
from repro.train.checkpoint import ZonedCheckpointStore
from repro.train.step import TrainHyper
from repro.train.optimizer import AdamWHyper
from repro.train.trainer import Trainer, TrainerConfig
from repro.zns import ZonedDevice


def make_cfg(preset: str):
    base = get_reduced("h2o-danube-1.8b")
    if preset == "tiny":
        return base.replace(num_layers=2, d_model=128, num_heads=4,
                            num_kv_heads=2, head_dim=32, d_ff=256,
                            vocab_size=512, sliding_window=None)
    if preset == "100m":
        return base.replace(num_layers=8, d_model=768, num_heads=12,
                            num_kv_heads=4, head_dim=64, d_ff=2048,
                            vocab_size=32000, sliding_window=None)
    raise SystemExit(f"unknown preset {preset}")


def bigram_corpus(n_seqs: int, seq_len: int, vocab: int, seed: int = 0):
    """Sequences from a sparse random bigram chain (learnable structure)."""
    rng = np.random.default_rng(seed)
    nxt = rng.integers(0, vocab, (vocab, 4))       # 4 successors per token
    toks = np.zeros((n_seqs, seq_len), np.int32)
    state = rng.integers(0, vocab, n_seqs)
    for t in range(seq_len):
        toks[:, t] = state
        pick = rng.integers(0, 4, n_seqs)
        state = nxt[state, pick]
    quality = rng.integers(0, 100, n_seqs).astype(np.int32)
    return toks, quality


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=("tiny", "100m"))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--min-quality", type=int, default=25)
    ap.add_argument("--ckpt", default="/tmp/zcsd_lm_ckpt.zns")
    args = ap.parse_args()

    cfg = make_cfg(args.preset)
    print(f"model: {cfg.param_count() / 1e6:.1f}M params "
          f"({cfg.num_layers}L x d{cfg.d_model})")

    # ---- corpus in zones, with device-side quality pushdown
    dev = ZonedDevice(num_zones=4, zone_bytes=32 * 1024 * 1024,
                      block_bytes=4096)
    store = ZoneDataStore(dev, seq_len=args.seq)
    toks, quality = bigram_corpus(2048, args.seq, cfg.vocab_size)
    store.append_records(0, toks[:1024], quality[:1024])
    store.append_records(1, toks[1024:], quality[1024:])
    pipe = ZoneDataPipeline(store, batch=args.batch,
                            min_quality=args.min_quality)

    # ---- zoned checkpoints: kill this script at any point and re-run it
    ckpt = ZonedCheckpointStore(args.ckpt, num_zones=8,
                                zone_bytes=64 * 1024 * 1024, keep=2)
    resumed = ckpt.latest_step()
    if resumed is not None:
        print(f"resuming from committed checkpoint at step {resumed}")

    epochs = max(1, args.steps * args.batch // 1500 + 1)
    batches = PrefetchLoader(pipe.batches([0, 1], epochs=epochs, seed=3),
                             depth=4, hedge_seconds=2.0)

    tcfg = TrainerConfig(
        total_steps=args.steps, checkpoint_every=50, log_every=20,
        hyper=TrainHyper(adamw=AdamWHyper(lr=1e-3, warmup_steps=20,
                                          total_steps=args.steps)))
    trainer = Trainer(cfg, tcfg, store=ckpt)
    t0 = time.time()
    last = trainer.run(batches)
    dt = time.time() - t0

    st = pipe.stats
    uniform = float(np.log(cfg.vocab_size))
    print(f"\ndone in {dt:.0f}s: loss {last.get('loss', float('nan')):.3f} "
          f"(uniform={uniform:.3f})")
    print(f"pushdown: kept {st.records_kept}/{st.records_seen} records, "
          f"saved {st.movement_saved / 1e6:.1f} MB of host transfers "
          f"({st.bytes_read_device / max(st.bytes_to_host, 1):.1f}x reduction)")
    print(f"checkpoints committed at steps {ckpt.steps()}, "
          f"zone resets (GC): {ckpt.device.stats['zone_resets']}")
    if trainer.history:
        first = trainer.history[0]["loss"] if resumed is None else None
        if first is not None:
            assert last["loss"] < first, "loss did not improve"
            print(f"loss improved {first:.3f} -> {last['loss']:.3f}")


if __name__ == "__main__":
    main()
