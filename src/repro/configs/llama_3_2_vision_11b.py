"""llama-3.2-vision-11b [vlm] — 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256; cross-attention image layers every 5th layer (offset 3).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision frontend (ViT + projector) is a STUB per the assignment:
``input_specs`` supplies precomputed patch embeddings in model space.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-11b",
    family="vlm",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    cross_attn_stride=5,
    cross_attn_offset=3,
    num_image_tokens=1601,      # one 448x448 tile -> (448/14)^2 + 1 + pad
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        num_layers=10, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, num_image_tokens=17, attn_chunk=32,
    )
