"""Pallas TPU kernel: flash-decode attention over a *zoned* KV cache.

The serving tier stores KV in append-only ZNS-style zones (a KV cache *is*
append-only storage; zone reset = sequence eviction). This kernel computes
one decode step directly against the zone pool — the "compute inside the
storage device" tier for serving:

  * grid = (B, MZ): for each sequence, stream that sequence's zones through
    VMEM one zone at a time. The BlockSpec index_map reads the *scalar-
    prefetched* zone table to pick zone ``zone_table[b, z]`` out of the HBM
    pool — the kernel reads zones in place and never materializes a
    contiguous per-sequence cache;
  * online softmax across zones: running (max, sum, acc) scratch in VMEM
    persists across the inner grid dimension;
  * out-of-range / unused zones are masked via the per-sequence length.

The zone-pool -> VMEM streaming obeys the same "small device memory" tiling
discipline as zone_filter: one zone block (ZL x KV x hd) in VMEM at a time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["paged_attention_pallas"]


def _decode_kernel(ztab_ref, len_ref, q_ref, k_ref, v_ref, out_ref,
                   m_ref, l_ref, acc_ref, *, zl: int):
    b = pl.program_id(0)
    z = pl.program_id(1)
    mz = pl.num_programs(1)

    @pl.when(z == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -1e30)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                     # [KV, G, hd]
    k = k_ref[0]                                     # [ZL, KV, hd]
    v = v_ref[0]
    hd = q.shape[-1]

    zone_id = ztab_ref[b, z]
    length = len_ref[b]
    pos = z * zl + jax.lax.iota(jnp.int32, zl)
    valid = (pos < length) & (zone_id >= 0)          # [ZL]

    qf = q.astype(jnp.float32) * hd ** -0.5
    logits = jnp.einsum("kgh,skh->kgs", qf, k.astype(jnp.float32))
    logits = jnp.where(valid[None, None, :], logits, -1e30)

    m_prev = m_ref[...]                              # [KV, G]
    m_new = jnp.maximum(m_prev, logits.max(-1))
    p = jnp.exp(logits - m_new[..., None])           # [KV, G, ZL]
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(-1)
    acc_ref[...] = acc_ref[...] * corr[..., None] + jnp.einsum(
        "kgs,skh->kgh", p, v.astype(jnp.float32))
    m_ref[...] = m_new

    @pl.when(z == mz - 1)
    def _final():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        out_ref[0] = out.astype(out_ref.dtype)


def paged_attention_pallas(q, k_zones, v_zones, zone_table, lengths, *,
                           interpret: bool = True):
    """q: [B, H, hd]; k_zones/v_zones: [NZ, ZL, KV, hd];
    zone_table: [B, MZ] int32 (-1 = unused); lengths: [B] int32.
    Returns [B, H, hd]."""
    B, H, hd = q.shape
    NZ, ZL, KV, _ = k_zones.shape
    MZ = zone_table.shape[1]
    G = H // KV

    qr = q.reshape(B, KV, G, hd)

    def _zone_block(b, z, ztab_ref, len_ref):
        # stream zone `zone_table[b, z]` (clamped for the -1 sentinel; its
        # contribution is masked in the kernel) out of the HBM zone pool
        return (jnp.maximum(ztab_ref[b, z], 0), 0, 0, 0)

    kernel = functools.partial(_decode_kernel, zl=ZL)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,        # zone_table, lengths
        grid=(B, MZ),
        in_specs=[
            pl.BlockSpec((1, KV, G, hd),
                         lambda b, z, ztab_ref, len_ref: (b, 0, 0, 0)),
            pl.BlockSpec((1, ZL, KV, hd), _zone_block),
            pl.BlockSpec((1, ZL, KV, hd), _zone_block),
        ],
        out_specs=pl.BlockSpec((1, KV, G, hd),
                               lambda b, z, ztab_ref, len_ref: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, G), jnp.float32),
            pltpu.VMEM((KV, G), jnp.float32),
            pltpu.VMEM((KV, G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(zone_table, lengths, qr, k_zones, v_zones)
    return out.reshape(B, H, hd)
